"""Telemetry overhead benchmark: the serve hot path with metrics off vs on.

Serves a warm-cache query pool through ``CountServer`` — every query is a
host-side cache hit, so the workload is pure instrumented-seam traffic
(batcher submit, dedup, cache lookup, reply scatter) with no kernel time to
hide behind.  Measures interleaved off/on rounds and gates the median
overhead of enabled metrics at <5%: the registry's whole design (bound
instruments, thread-confined shards, an ``enabled`` check before any
allocation) exists to keep always-on telemetry invisible, and this bench is
the enforcement.  Run as a script it emits ``BENCH_obs.json``.

  PYTHONPATH=src python -m benchmarks.obs_overhead [--json BENCH_obs.json]
      [--smoke]
"""
from __future__ import annotations

import statistics
from typing import List

import numpy as np

from repro import obs
from repro.serve import CountServer

from .common import Row, timeit

ROWS, ITEMS, POOL = 4096, 48, 256
BATCH = 64
ROUNDS = 5               # interleaved off/on measurement rounds
GATE_PCT = 5.0           # enabled metrics may cost at most this much


def _workload(pool_size: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    tx = [tuple(sorted(rng.choice(ITEMS, size=rng.integers(1, 6),
                                  replace=False).tolist()))
          for _ in range(ROWS)]
    pool = [tuple(rng.choice(ITEMS, size=rng.integers(1, 4),
                             replace=False).tolist())
            for _ in range(pool_size)]
    return tx, pool


def _serve_pool(server: CountServer, pool, batch: int) -> None:
    for s in range(0, len(pool), batch):
        for i, key in enumerate(pool[s:s + batch]):
            server.submit(f"c{i % 8}", [key])
        server.flush()


def run(record: List[dict] | None = None, *, smoke: bool = False) -> List[Row]:
    pool_size = 64 if smoke else POOL
    rounds = 2 if smoke else ROUNDS
    tx, pool = _workload(pool_size)
    server = CountServer(tx, cache=True)
    _serve_pool(server, pool, BATCH)          # prime: every later rep is warm

    # Interleaved A/B rounds so drift (thermal, sibling CI load) hits both
    # configurations equally; the gate compares medians across rounds.
    off_us, on_us = [], []
    try:
        for _ in range(rounds):
            obs.disable_all()
            off_us.append(timeit(lambda: _serve_pool(server, pool, BATCH),
                                 repeats=1, warmup=1) / pool_size)
            obs.configure(metrics=True)
            on_us.append(timeit(lambda: _serve_pool(server, pool, BATCH),
                                repeats=1, warmup=1) / pool_size)
    finally:
        obs.reset()                           # restore session defaults

    off = statistics.median(off_us)
    on = statistics.median(on_us)
    overhead_pct = (on - off) / off * 100.0

    tag = f"obs[N={ROWS},pool={pool_size}]"
    rows: List[Row] = [
        (f"{tag}/metrics_off", off, "warm-cache serve, obs.disable_all()"),
        (f"{tag}/metrics_on", on, f"overhead={overhead_pct:+.1f}%"),
    ]
    if record is not None:
        record.append({"variant": "overhead", "batch": BATCH,
                       "us_off": off, "us_on": on,
                       "overhead_pct": overhead_pct,
                       "gate_pct": GATE_PCT, "rounds": rounds})

    if not smoke:
        assert overhead_pct < GATE_PCT, (
            f"enabled metrics cost {overhead_pct:.1f}% on the warm serve "
            f"path (gate {GATE_PCT}%): off={off:.1f}us on={on:.1f}us/query")
    return rows


def main() -> None:
    import argparse
    import json

    import jax

    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default="BENCH_obs.json")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny pool, no gate — CI liveness check only")
    args = ap.parse_args()

    record: List[dict] = []
    rows = run(record, smoke=args.smoke)
    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")

    if not args.smoke:
        payload = {
            "bench": "obs_overhead",
            "backend": jax.default_backend(),
            "problem": {"rows": ROWS, "items": ITEMS, "pool": POOL,
                        "batch": BATCH, "rounds": ROUNDS},
            "rows": record,
        }
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=2)
        print(f"wrote {args.json} ({len(record)} records)")


if __name__ == "__main__":
    main()
