"""Streaming-engine benchmark: chunked out-of-core sweep vs single-pass dense.

Measures the counting sweep at several chunk sizes (and the dense single pass
as the resident baseline), verifies bit-identical counts against the blocked
jnp oracle, and — run as a script — emits a ``BENCH_streaming.json`` perf
record (the CI artifact tracking streaming overhead across PRs).

  PYTHONPATH=src python -m benchmarks.streaming [--json BENCH_streaming.json]
"""
from __future__ import annotations

import json
from typing import List

import numpy as np

from repro.kernels.itemset_count import itemset_counts, itemset_counts_ref_blocked
from repro.mining import streaming_counts

from .common import Row, timeit


def _problem(n: int, k: int, w: int, c: int, seed: int = 0):
    import jax.numpy as jnp

    rng = np.random.default_rng(seed)
    tx = (rng.integers(0, 2 ** 32, (n, w), dtype=np.uint32)
          & rng.integers(0, 2 ** 32, (n, w), dtype=np.uint32))
    tgt = np.zeros((k, w), np.uint32)
    for i in range(k):
        for b in rng.integers(0, 32 * w, 3):
            tgt[i, b >> 5] |= np.uint32(1) << np.uint32(b & 31)
    wts = rng.integers(0, 3, (n, c)).astype(np.int32)
    return tx, tgt, wts, jnp


N, K, W, C = 65536, 256, 4, 2
CHUNKS = [8192, 16384, 32768]


def run(record: List[dict] | None = None) -> List[Row]:
    tx, tgt, wts, jnp = _problem(N, K, W, C)
    want = np.asarray(itemset_counts_ref_blocked(
        jnp.asarray(tx), jnp.asarray(tgt), jnp.asarray(wts)))

    rows: List[Row] = []
    tag = f"streaming[N={N},K={K},W={W}]"

    tx_d, tgt_d, wts_d = jnp.asarray(tx), jnp.asarray(tgt), jnp.asarray(wts)
    out = np.asarray(itemset_counts(tx_d, tgt_d, wts_d))
    assert (out == want).all()
    us_dense = timeit(
        lambda: itemset_counts(tx_d, tgt_d, wts_d).block_until_ready())
    rows.append((f"{tag}/dense_single_pass", us_dense, "resident_baseline"))
    if record is not None:
        record.append({"variant": "dense_single_pass", "chunk_rows": None,
                       "us_per_sweep": us_dense, "n_chunks": 1, "match": True})

    for chunk in CHUNKS:
        out = np.asarray(streaming_counts(tx, tgt, wts, chunk_rows=chunk))
        match = bool((out == want).all())
        assert match, chunk
        us = timeit(lambda: np.asarray(
            streaming_counts(tx, tgt, wts, chunk_rows=chunk)))
        n_chunks = -(-N // chunk)
        rows.append((f"{tag}/chunk={chunk}", us,
                     f"chunks={n_chunks};overhead_vs_dense="
                     f"{us / max(us_dense, 1e-9):.2f}x"))
        if record is not None:
            record.append({"variant": "streaming", "chunk_rows": chunk,
                           "us_per_sweep": us, "n_chunks": n_chunks,
                           "match": match})
    return rows


def main() -> None:
    import argparse

    import jax

    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default="BENCH_streaming.json")
    args = ap.parse_args()

    record: List[dict] = []
    rows = run(record)
    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")

    payload = {
        "bench": "streaming",
        "backend": jax.default_backend(),
        "problem": {"n": N, "k": K, "w": W, "c": C},
        "rows": record,
    }
    with open(args.json, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"wrote {args.json} ({len(record)} records)")


if __name__ == "__main__":
    main()
