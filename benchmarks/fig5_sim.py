"""Paper Figure 5 analogue (simulation study, scaled to this 1-core CPU).

Bernoulli transactions (p_X=0.125), imbalanced target (p_Y), min-support as
in the paper (scaled): compares
  * full FP-growth over the entire DB  (the paper's baseline, Fig 5a/d),
  * MRA with GFP-growth                 (Fig 5b/e),
  * MRA on the dense/TPU engine,
and reports the runtime RATIO (Fig 5c/f) — the paper's headline claim is that
the ratio grows as p_Y falls (10-80x at p_Y=0.01 at their scale).
Rule sets are asserted identical across engines (exactness).
"""
from __future__ import annotations

import time
from typing import List

from repro.core import full_fpgrowth_rules, minority_report
from repro.data import bernoulli_db
from repro.mining import minority_report_dense

from .common import Row


def run() -> List[Row]:
    rows: List[Row] = []
    p_x = 0.125
    # min-support scaled so the rare-class min-count C* stays in the paper's
    # "low support" regime (a few counts) without letting the pure-Python
    # full-FP-growth baseline's lattice explode past this 1-core container.
    for p_y, sup_cells in (
        (0.01, ((2500, 40, 1.2e-3), (5000, 50, 8e-4), (10000, 60, 6e-4))),
        (0.1, ((2500, 40, 1.2e-2), (5000, 50, 8e-3), (10000, 60, 6e-3))),
    ):
        for n_tx, n_items, min_sup in sup_cells:
            tx, y = bernoulli_db(n_tx, n_items, p_x, p_y, seed=n_tx + n_items)
            if int(y.sum()) == 0:
                continue
            t0 = time.perf_counter()
            base = full_fpgrowth_rules(tx, y, min_support=min_sup,
                                       min_confidence=0.0)
            t_full = time.perf_counter() - t0
            t0 = time.perf_counter()
            mra = minority_report(tx, y, min_support=min_sup,
                                  min_confidence=0.0)
            t_mra = time.perf_counter() - t0
            t0 = time.perf_counter()
            dense = minority_report_dense(tx, y, min_support=min_sup,
                                          min_confidence=0.0)
            t_dense = time.perf_counter() - t0

            a = {r.antecedent for r in base}
            b = {r.antecedent for r in mra.rules}
            c = {r.antecedent for r in dense.rules}
            assert a == b == c, (len(a), len(b), len(c))

            tag = f"fig5[pY={p_y},n={n_tx},items={n_items}]"
            ratio = t_full / max(t_mra, 1e-9)
            rows.append((f"{tag}/fpgrowth_full", t_full * 1e6,
                         f"rules={len(a)}"))
            rows.append((f"{tag}/mra_gfp", t_mra * 1e6,
                         f"speedup_vs_full={ratio:.1f}x"))
            rows.append((f"{tag}/mra_dense", t_dense * 1e6,
                         f"speedup_vs_full={t_full / max(t_dense, 1e-9):.1f}x"))
    return rows
