"""Rule-serving benchmark: cold vs warm minority-rule queries + shard parity.

Serves a fixed pool of antecedent rule queries through ``RuleServer`` at
several batch sizes, cold (rule cache AND count cache off: every query pays
the composed counting pass) and warm (both caches on, pool primed: verdicts
come straight from the rule cache).  Then checks 1/2/4-shard stores serve
the identical rule set (``rules_for`` verdicts and the ``top_rules`` sweep)
— the all-reduce must be invisible to the rule math.  Run as a script it
emits ``BENCH_rules.json``; the perf gate is warm >= 5x cold at batch >= 16.

  PYTHONPATH=src python -m benchmarks.rule_serve [--json BENCH_rules.json]
"""
from __future__ import annotations

import json
from typing import List

import numpy as np

from repro.core.mra import Rule
from repro.data import bernoulli_db
from repro.kernels.itemset_count import itemset_counts
from repro.mining import DenseDB, encode_targets
from repro.serve import CountServer, RuleServer

from .common import Row, timeit

ROWS, ITEMS, POOL = 16384, 48, 256
BATCHES = [1, 4, 16, 64]
MIN_CONF = 0.05
THETA = 0.004   # ~ rare-class item frequency: the top_rules sweep is non-empty
SHARDS = [1, 2, 4]


def _workload(seed: int = 0):
    tx, y = bernoulli_db(ROWS, ITEMS, p_x=0.15, p_y=0.05, seed=seed)
    rng = np.random.default_rng(seed + 1)
    pool = [tuple(rng.choice(ITEMS, size=rng.integers(1, 4),
                             replace=False).tolist())
            for _ in range(POOL)]
    return tx, y, pool


def _serve_pool(ruler: RuleServer, pool, batch: int):
    out = {}
    for s in range(0, len(pool), batch):
        chunk = pool[s:s + batch]
        for key, rule in zip(chunk,
                             ruler.rules_for(chunk, min_conf=MIN_CONF)):
            out[tuple(sorted(set(key), key=repr))] = rule
    return out


def _expected_rules(tx, y, pool):
    """Independent oracle: fresh dense counts -> host rule math."""
    import jax.numpy as jnp

    keys = [tuple(sorted(set(k), key=repr)) for k in pool]
    ddb = DenseDB.encode(tx, classes=list(y), n_classes=2)
    rows = np.asarray(itemset_counts(
        ddb.bits, jnp.asarray(encode_targets(keys, ddb.vocab)), ddb.weights))
    want = {}
    for key, row in zip(keys, rows):
        cnt, gcnt = int(row[1]), int(row.sum()) - int(row[1])
        conf = cnt / (cnt + gcnt) if (cnt + gcnt) else 0.0
        want[key] = (Rule(key, 1, cnt / len(tx), conf, cnt, gcnt)
                     if conf >= MIN_CONF else None)
    return want


def run(record: List[dict] | None = None) -> List[Row]:
    tx, y, pool = _workload()
    want = _expected_rules(tx, y, pool)

    rows: List[Row] = []
    tag = f"rules[N={ROWS},pool={POOL}]"

    us_cold, us_warm = {}, {}
    for batch in BATCHES:
        # ---- cold: no rule cache, no count cache — every query counts ------
        cold = RuleServer(CountServer(tx, classes=list(y), cache=False),
                          cache=False)
        got = _serve_pool(cold, pool, batch)
        assert got == want, f"cold batch={batch}: served rules != oracle"
        us = timeit(lambda: _serve_pool(cold, pool, batch),
                    repeats=3, warmup=1) / POOL
        us_cold[batch] = us
        rows.append((f"{tag}/batch={batch}(cold)", us, "rule_cache=off"))

        # ---- warm: both caches on, pool primed — verdicts are cache hits ---
        warm = RuleServer(CountServer(tx, classes=list(y), cache=True),
                          cache=True)
        got = _serve_pool(warm, pool, batch)          # prime (all misses)
        assert got == want, f"warm batch={batch}: served rules != oracle"
        us = timeit(lambda: _serve_pool(warm, pool, batch),
                    repeats=3, warmup=1) / POOL
        us_warm[batch] = us
        speedup = us_cold[batch] / us
        rows.append((f"{tag}/batch={batch}(warm)", us,
                     f"warm_vs_cold={speedup:.1f}x;hit_rate="
                     f"{warm.cache.hit_rate:.2f}"))
        if record is not None:
            record.append({
                "variant": "rules_for", "batch": batch,
                "us_per_query_cold": us_cold[batch],
                "us_per_query_warm": us,
                "qps_cold": 1e6 / us_cold[batch], "qps_warm": 1e6 / us,
                "warm_vs_cold_speedup": speedup,
                "meets_5x_gate": (speedup >= 5.0 if batch >= 16 else None),
                "rule_cache_hit_rate": warm.cache.hit_rate,
            })

    # ---- shard parity: 1/2/4-shard stores serve the identical rule set -----
    reference = None
    for n in SHARDS:
        ruler = RuleServer(CountServer(tx, classes=list(y), shards=n))
        served = _serve_pool(ruler, pool, 64)
        assert served == want, f"shards={n}: served rules != oracle"
        top = ruler.top_rules(THETA, MIN_CONF, optimal=True)
        if reference is None:
            reference = top
        parity = served == want and top == reference
        us = timeit(lambda: _serve_pool(ruler, pool, 64),
                    repeats=3, warmup=0) / POOL
        rows.append((f"{tag}/shards={n}", us,
                     f"parity={parity};top_rules={len(top)}"))
        if record is not None:
            record.append({"variant": "shard_parity", "shards": n,
                           "us_per_query_warm": us, "parity": parity,
                           "top_rules": len(top)})
        assert parity, f"shards={n}: rule parity broken"
    return rows


def main() -> None:
    import argparse

    import jax

    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default="BENCH_rules.json")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny problem; exactness asserts only")
    args = ap.parse_args()

    if args.smoke:
        global ROWS, POOL
        ROWS, POOL = 2048, 64

    record: List[dict] = []
    rows = run(record)
    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")

    gate = [r for r in record
            if r["variant"] == "rules_for" and r["batch"] >= 16]
    payload = {
        "bench": "rules",
        "backend": jax.default_backend(),
        "problem": {"rows": ROWS, "items": ITEMS, "pool": POOL,
                    "batches": BATCHES, "min_conf": MIN_CONF,
                    "theta": THETA, "shards": SHARDS},
        "warm_5x_gate_met": all(r["meets_5x_gate"] for r in gate),
        "rows": record,
    }
    with open(args.json, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"wrote {args.json} ({len(record)} records, 5x gate "
          f"{'MET' if payload['warm_5x_gate_met'] else 'MISSED'})")


if __name__ == "__main__":
    main()
