"""Out-of-core mining demo: N beyond device residency, with kill/resume.

Builds a transaction DB, mines it with the streaming engine in small host
chunks (simulating a DB far larger than device memory), and demonstrates the
per-chunk checkpoint: the first mine is killed mid-level, the second resumes
from the last completed chunk and still produces the exact rule set of the
single-pass dense engine.

  PYTHONPATH=src python examples/streaming_bigdata.py [rows] [chunk_rows]
"""
import os
import sys
import tempfile
import time

from repro.core import minority_report
from repro.data import bernoulli_db
from repro.mining import StreamingDB, minority_report_dense, streaming_mine_frequent
from repro.mining.distributed import MiningCheckpoint


def main() -> None:
    rows = int(sys.argv[1]) if len(sys.argv) > 1 else 20000
    chunk_rows = int(sys.argv[2]) if len(sys.argv) > 2 else 1024

    tx, y = bernoulli_db(rows, 40, p_x=0.15, p_y=0.03, seed=7)
    print(f"db: {rows} rows, chunked at {chunk_rows} rows/chunk")

    # ---- streaming MRA == host-faithful MRA --------------------------------
    t0 = time.time()
    res = minority_report_dense(tx, y, min_support=0.002, min_confidence=0.02,
                                streaming=True, chunk_rows=chunk_rows)
    t_stream = time.time() - t0
    host = minority_report(tx, y, min_support=0.002, min_confidence=0.02)
    a = {r.antecedent: (r.count, r.g_count) for r in res.rules}
    b = {r.antecedent: (r.count, r.g_count) for r in host.rules}
    assert a == b, (len(a), len(b))
    print(f"{res.engine} engine: {len(res.rules)} rules in {t_stream:.2f}s "
          f"(== host-faithful MRA)")

    # ---- kill/resume: durable per-chunk progress ---------------------------
    sdb = StreamingDB.encode(tx, chunk_rows=chunk_rows)
    fd, ckpt_path = tempfile.mkstemp(suffix=".mine.json")
    os.close(fd)
    ckpt = MiningCheckpoint(ckpt_path)
    budget = sdb.n_chunks + sdb.n_chunks // 2  # die mid-way through level 2

    class _Preempted(Exception):
        pass

    seen = []

    def die_midway(level, chunk):
        seen.append((level, chunk))
        if len(seen) >= budget:
            raise _Preempted()

    try:
        streaming_mine_frequent(sdb, min_count=rows * 0.01, checkpoint=ckpt,
                                on_chunk=die_midway)
        print("db too small to be preempted mid-level; try more rows")
    except _Preempted:
        level, chunk = seen[-1]
        print(f"killed at level {level}, chunk {chunk + 1}/{sdb.n_chunks}")

    resumed = []
    got = streaming_mine_frequent(sdb, min_count=rows * 0.01, checkpoint=ckpt,
                                  on_chunk=lambda l, c: resumed.append((l, c)))
    want = streaming_mine_frequent(sdb, min_count=rows * 0.01)
    assert got == want
    print(f"resumed at level {resumed[0][0]}, chunk {resumed[0][1] + 1} — "
          f"{len(resumed)} chunk-counts instead of {len(seen) + len(resumed)}"
          f"+; {len(got)} frequent itemsets, identical to uninterrupted run")


if __name__ == "__main__":
    main()
