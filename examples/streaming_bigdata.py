"""Out-of-core mining demo: N beyond device residency, with kill/resume.

Builds a transaction DB, mines it with the streaming engine in small host
chunks (simulating a DB far larger than device memory), and demonstrates the
per-chunk checkpoint of the unified mining driver: the first mine is killed
mid-level, the second resumes from the last completed chunk and still
produces the exact rule set of the single-pass dense engine.

  PYTHONPATH=src python examples/streaming_bigdata.py [--rows N] \
      [--chunk-rows C] [--ckpt mine.ckpt.json] [--backend auto]

With ``--ckpt PATH`` the resumable mine runs through the unified driver
(``repro.mining.driver``) against that DURABLE path: Ctrl-C it mid-run,
re-run the same command, and it picks up from the last completed chunk —
the same ``MiningCheckpoint`` contract every backend (dense, streaming,
distributed, versioned serving store) now shares.  Without ``--ckpt`` the
kill/resume cycle is simulated in-process under a temp file.

``--backend`` selects the counting engine for the kill/resume mine:
``streaming`` (default — the out-of-core demo this example is about),
``dense``, ``gfp`` (the guided FP-growth hybrid), or ``auto`` — which asks
the adaptive chooser (``repro.mining.chooser``) to pick from MEASURED
dataset traits and prints its decision and the traits it was based on.
"""
import argparse
import os
import tempfile
import time

from repro import obs
from repro.core import minority_report
from repro.data import bernoulli_db
from repro.mining import (DenseDB, StreamingBackend, StreamingDB,
                          backend_for_db, mine_frequent_backend,
                          minority_report_dense)
from repro.mining.distributed import MiningCheckpoint


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--rows", type=int, default=20000)
    ap.add_argument("--chunk-rows", type=int, default=1024)
    ap.add_argument("--ckpt", default=None,
                    help="durable MiningCheckpoint path: kill this process "
                         "mid-mine and re-run to resume from the last chunk")
    ap.add_argument("--backend", default="streaming",
                    choices=["streaming", "auto", "dense", "gfp"],
                    help="counting engine for the kill/resume mine; auto "
                         "consults the adaptive chooser over measured traits")
    args = ap.parse_args()
    rows, chunk_rows = args.rows, args.chunk_rows

    tx, y = bernoulli_db(rows, 40, p_x=0.15, p_y=0.03, seed=7)
    print(f"db: {rows} rows, chunked at {chunk_rows} rows/chunk")

    # ---- streaming MRA == host-faithful MRA --------------------------------
    t0 = time.time()
    res = minority_report_dense(tx, y, min_support=0.002, min_confidence=0.02,
                                streaming=True, chunk_rows=chunk_rows)
    t_stream = time.time() - t0
    host = minority_report(tx, y, min_support=0.002, min_confidence=0.02)
    a = {r.antecedent: (r.count, r.g_count) for r in res.rules}
    b = {r.antecedent: (r.count, r.g_count) for r in host.rules}
    assert a == b, (len(a), len(b))
    print(f"{res.engine} engine: {len(res.rules)} rules in {t_stream:.2f}s "
          f"(== host-faithful MRA)")

    # ---- kill/resume through the unified driver ----------------------------
    sdb = StreamingDB.encode(tx, chunk_rows=chunk_rows)
    if args.backend == "streaming":
        backend = StreamingBackend(sdb)
    else:
        # the chooser path: measure the encoded DB, pick (or force) an
        # engine, and say why — every engine speaks the same driver protocol,
        # so the kill/resume flow below is unchanged
        name = None if args.backend == "auto" else args.backend
        backend, choice = backend_for_db(DenseDB.encode(tx), name=name)
        print(f"backend: {choice.name} ({choice.reason})")
        if choice.traits is not None:
            t = choice.traits
            print(f"traits: {t.n_rows} rows ({t.n_unique} unique, dedup "
                  f"{t.dedup_ratio:.2f}), density {t.density:.2f}, "
                  f"skew {t.skew:.1f}x, {t.nbytes} bytes")
    min_count = rows * 0.01

    if args.ckpt:
        # durable mode: progress survives THIS process — kill and re-run
        ckpt = MiningCheckpoint(args.ckpt)
        state = ckpt.load_state()
        if state is not None:
            partial = state.get("partial")
            where = (f"mid-level {partial['level']}, chunk "
                     f"{partial['next_chunk']}" if partial
                     else f"level {state['level']} complete")
            print(f"resuming {args.ckpt}: {where}")
        chunks = []
        got = mine_frequent_backend(
            backend, min_count, checkpoint=ckpt,
            on_chunk=lambda lvl, c: chunks.append((lvl, c)))
        want = mine_frequent_backend(backend, min_count)
        assert got == want
        print(f"driver mine complete: {len(got)} frequent itemsets after "
              f"{len(chunks)} chunk-counts this run (== uninterrupted run); "
              f"delete {args.ckpt} to start fresh")
        print(obs.summary_line())
        return

    # simulated mode: preempt mid-level in-process, then resume
    fd, ckpt_path = tempfile.mkstemp(suffix=".mine.json")
    os.close(fd)
    ckpt = MiningCheckpoint(ckpt_path)
    n_chunks = backend.n_count_chunks
    budget = n_chunks + n_chunks // 2          # die mid-way through level 2

    class _Preempted(Exception):
        pass

    seen = []

    def die_midway(level, chunk):
        seen.append((level, chunk))
        if len(seen) >= budget:
            raise _Preempted()

    preempted = False
    try:
        mine_frequent_backend(backend, min_count, checkpoint=ckpt,
                              on_chunk=die_midway)
        print("db too small to be preempted mid-level; try more rows")
    except _Preempted:
        preempted = True
        level, chunk = seen[-1]
        print(f"killed at level {level}, chunk {chunk + 1}/{n_chunks}")

    if preempted:
        resumed = []
        got = mine_frequent_backend(backend, min_count, checkpoint=ckpt,
                                    on_chunk=lambda l, c:
                                    resumed.append((l, c)))
        want = mine_frequent_backend(backend, min_count)
        assert got == want
        print(f"resumed at level {resumed[0][0]}, chunk {resumed[0][1] + 1}"
              f" — {len(resumed)} chunk-counts instead of "
              f"{len(seen) + len(resumed)}+; {len(got)} frequent itemsets, "
              f"identical to uninterrupted run")
    os.unlink(ckpt_path)
    print(obs.summary_line())


if __name__ == "__main__":
    main()
