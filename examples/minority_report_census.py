"""End-to-end driver: minority-class rule mining on census-like data —
the paper's Fig-6 experiment shape (imbalanced 'salary' target, 115 items,
p_Y-resampled), comparing:

  1. full FP-growth over the whole DB (the "well-known solution" baseline),
  2. the Minority-Report Algorithm (paper-faithful GFP-growth),
  3. the TPU-native dense engine (bitmap + Pallas counting kernel),
  4. the streaming out-of-core engine (same kernel, N swept in host chunks).

All four must produce identical rule sets; times illustrate the paper's
speedup claim (GFP focuses work on the rare class) and the streaming
engine's overhead for unbounded-N operation.

  PYTHONPATH=src python examples/minority_report_census.py [p_y ...]
"""
import sys
import time

from repro.core import full_fpgrowth_rules, minority_report
from repro.data import census_like_db
from repro.mining import minority_report_dense


def run(p_y: float, rows: int = 8000, min_support: float = 5e-4,
        min_conf: float = 0.3) -> None:
    tx, y = census_like_db(rows, p_y, seed=42)
    print(f"\n--- p_y={p_y} rows={rows} rare={int(y.sum())} "
          f"min_sup={min_support} ---")

    t0 = time.time()
    base = full_fpgrowth_rules(tx, y, min_support=min_support,
                               min_confidence=min_conf)
    t_full = time.time() - t0

    t0 = time.time()
    mra = minority_report(tx, y, min_support=min_support,
                          min_confidence=min_conf)
    t_mra = time.time() - t0

    t0 = time.time()
    dense = minority_report_dense(tx, y, min_support=min_support,
                                  min_confidence=min_conf)
    t_dense = time.time() - t0

    t0 = time.time()
    stream = minority_report_dense(tx, y, min_support=min_support,
                                   min_confidence=min_conf,
                                   streaming=True, chunk_rows=1024)
    t_stream = time.time() - t0

    a = {r.antecedent: (r.count, r.g_count) for r in base}
    b = {r.antecedent: (r.count, r.g_count) for r in mra.rules}
    c = {r.antecedent: (r.count, r.g_count) for r in dense.rules}
    d = {r.antecedent: (r.count, r.g_count) for r in stream.rules}
    assert a == b == c == d, (len(a), len(b), len(c), len(d))

    print(f"rules: {len(b)} (identical across engines)")
    print(f"full FP-growth: {t_full:8.2f}s   (baseline)")
    print(f"MRA/GFP-growth: {t_mra:8.2f}s   ({t_full / max(t_mra, 1e-9):5.1f}x)")
    print(f"dense (kernel): {t_dense:8.2f}s   ({t_full / max(t_dense, 1e-9):5.1f}x)")
    print(f"streaming     : {t_stream:8.2f}s   ({t_full / max(t_stream, 1e-9):5.1f}x, "
          f"out-of-core chunks of 1024 rows)")
    for r in mra.rules[:5]:
        print("   ", r)


def main() -> None:
    pys = [float(a) for a in sys.argv[1:]] or [0.01, 0.05, 0.25]
    for p_y in pys:
        run(p_y)


if __name__ == "__main__":
    main()
