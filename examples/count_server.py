"""Online count-serving demo — the GFP count server end to end.

The paper's multitude-targeted contract ("the count of a given large list of
itemsets") as an online service: an encoded DB stays RESIDENT between
requests, many small client queries are coalesced into one guided counting
pass, repeated queries hit an (itemset, version) cache, and appended
transaction batches are folded in incrementally (§5.2) without re-encoding
the history.

Serving API (submit / flush):

    server = CountServer(transactions, classes=y)    # encode once, keep resident
    t1 = server.submit("client-a", [(2, 5), (7,)])   # queue queries (a ticket each)
    t2 = server.submit("client-b", [(5, 2)])         # same target: deduped across clients
    results = server.flush()                         # ONE batched counting pass
    results[t1]    # (2, C) int32 rows, aligned with client-a's submission order
    results[t2]    # (1, C) — bit-identical to client-a's (2, 5) row
    server.query([(2, 5)])                           # submit+flush shorthand

    server.append(new_tx, classes=new_y)             # version += 1 (cache invalidated)
    server.mine(theta)                               # exact frequent set, engine-mined
    server.append(more_tx, classes=more_y)           # ... maintained via §5.2 pigeonhole
    server.frequent                                  #     candidates + one guided recount

  PYTHONPATH=src python examples/count_server.py [rows] [append_rows]
"""
import sys
import time

import numpy as np

from repro.core import ItemOrder, TISTree, brute_force_counts
from repro.data import bernoulli_db
from repro.mining import DenseDB, dense_gfp_counts
from repro.serve import CountServer


def main() -> None:
    rows = int(sys.argv[1]) if len(sys.argv) > 1 else 8000
    append_rows = int(sys.argv[2]) if len(sys.argv) > 2 else 1000

    tx, y = bernoulli_db(rows, 32, p_x=0.15, p_y=0.05, seed=3)
    server = CountServer(tx, classes=list(y))
    st = server.store
    print(f"resident {st.resident} DB: {st.base_rows} unique rows of "
          f"{st.n_rows}, {st.vocab.size} items, version {st.version}")

    # ---- micro-batched serving: many clients, one counting pass ------------
    rng = np.random.default_rng(0)
    queries = {f"client-{c}": [tuple(rng.choice(32, size=k + 1,
                                                replace=False).tolist())
                               for k in rng.integers(0, 3, 6)]
               for c in range(4)}
    tickets = {c: server.submit(c, qs) for c, qs in queries.items()}
    t0 = time.time()
    results = server.flush()
    n_q = sum(len(qs) for qs in queries.values())
    print(f"flushed {n_q} queries from {len(queries)} clients in one pass "
          f"({1e3 * (time.time() - t0):.1f} ms, "
          f"{server.store.kernel_launches} launches, "
          f"{server.batcher.n_deduped} deduped)")

    # exactness: identical to the GFP-growth contract on a fresh dense encode
    counts = {a: sum(1 for t in tx if a in t) for a in range(32)}
    tis = TISTree(ItemOrder.from_counts(counts))
    flat = sorted({k for qs in queries.values() for k in qs})
    for k in flat:
        tis.insert(list(k), target=True)
    gfp = dense_gfp_counts(tis, DenseDB.encode(tx, classes=list(y),
                                               n_classes=2))
    for client, qs in queries.items():
        for i, k in enumerate(qs):
            key = tuple(sorted(set(k), key=repr))
            assert (results[tickets[client]][i] == gfp[key]).all()
    oracle = brute_force_counts(tx, flat)
    assert all(int(gfp[key].sum()) == oracle[key]
               for key in (tuple(sorted(set(k), key=repr)) for k in flat))
    print(f"all {n_q} served rows bit-identical to dense_gfp_counts "
          f"(+ brute-force oracle) at v{server.store.version}")

    # ---- hot queries: the (itemset, version) cache -------------------------
    hot = flat[:8]
    server.query(hot)                       # warm
    t0 = time.time()
    server.query(hot)                       # all hits: no device work
    t_hot = time.time() - t0
    print(f"hot repeat of {len(hot)} queries: {1e6 * t_hot:.0f} us "
          f"(cache hit rate {server.cache.hit_rate:.2f})")

    # ---- growth: appends bump the version and refresh the frequent set -----
    theta = 0.06
    freq = server.mine(theta)
    print(f"mined {len(freq)} frequent itemsets at theta={theta}")
    before = server.query(hot)
    batch, yb = bernoulli_db(append_rows, 32, p_x=0.22, p_y=0.05, seed=9)
    v = server.append(batch, classes=list(yb))
    after = server.query(hot)               # version changed: cache misses
    changed = int((before != after).any(axis=1).sum())
    print(f"append -> v{v} (+{append_rows} rows): {changed}/{len(hot)} hot "
          f"counts changed, frequent set -> {len(server.frequent)} "
          f"(engine-recounted §5.2 candidates)")

    from repro.core import mine_frequent
    from repro.core.incremental import ceil_count
    full = mine_frequent([list(t) for t in tx] + [list(t) for t in batch],
                         ceil_count(theta * (rows + append_rows)))
    assert server.frequent == full
    print(f"incremental frequent set == full re-mine ({len(full)} itemsets)")


if __name__ == "__main__":
    main()
