"""Framework integration: multitude-targeted mining over an LM training
corpus — the analytics service the mining engine provides inside the training
framework (DESIGN.md §4).

Documents (token sequences from the data pipeline) become transactions (their
token-id sets); the Minority-Report Algorithm mines which token combinations
are over-represented in a minority document class (e.g. a rare quality/label
bucket) — the same mesh-sharded counting kernel the trainer uses.

  PYTHONPATH=src python examples/corpus_pattern_mining.py
"""
import numpy as np

from repro.data import TokenPipeline
from repro.mining import minority_report_dense


def main() -> None:
    vocab = 512
    pipe = TokenPipeline(vocab_size=vocab, seq_len=64, global_batch=64, seed=7)
    rng = np.random.default_rng(7)

    docs, labels = [], []
    marker_tokens = [11, 23, 37]   # planted minority-class pattern
    for step in range(30):
        batch = pipe.batch_at(step)["tokens"]
        for row in batch:
            rare = rng.random() < 0.05
            toks = set(int(t) for t in row)
            if rare:
                toks |= set(marker_tokens)
            docs.append(sorted(toks))
            labels.append(int(rare))

    res = minority_report_dense(
        docs, labels, min_support=0.01, min_confidence=0.6)
    print(f"{len(docs)} documents, {sum(labels)} rare; "
          f"{len(res.rules)} minority-class token rules")
    planted = [r for r in res.rules
               if set(r.antecedent) & set(marker_tokens)]
    print(f"rules touching planted marker tokens: {len(planted)}")
    for r in sorted(planted, key=lambda r: -len(r.antecedent))[:5]:
        print("  ", r)
    got = {tuple(sorted(marker_tokens))} & {r.antecedent for r in res.rules}
    assert got, "planted pattern not recovered!"
    print("planted pattern recovered exactly:", got)


if __name__ == "__main__":
    main()
