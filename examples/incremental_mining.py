"""§5.2 extension demo: incremental frequent-itemset mining with GFP-guided
recounts — a stream of transaction batches arrives; the miner keeps the exact
frequent-itemset set of everything seen so far without ever re-mining the full
history, by guided (targeted) recounts in the big historical tree.

  PYTHONPATH=src python examples/incremental_mining.py
"""
import time

import numpy as np

from repro.core import mine_frequent
from repro.core.incremental import IncrementalMiner
from repro.data import bernoulli_db


def main() -> None:
    theta = 0.05
    tx0, _ = bernoulli_db(4000, 40, p_x=0.15, p_y=0.0, seed=0)
    miner = IncrementalMiner(theta)
    t0 = time.time()
    freq = miner.fit(tx0)
    print(f"bootstrap: {len(tx0)} rows -> {len(freq)} frequent itemsets "
          f"({time.time() - t0:.2f}s)")

    history = list(tx0)
    for i in range(1, 4):
        batch, _ = bernoulli_db(500, 40, p_x=0.15 + 0.02 * i, p_y=0.0, seed=i)
        t0 = time.time()
        freq = miner.update(batch)
        t_inc = time.time() - t0
        history += batch

        t0 = time.time()
        full = mine_frequent(history, max(1, int(np.ceil(theta * len(history) - 1e-9))))
        t_full = time.time() - t0
        assert freq == full, (len(freq), len(full))
        print(f"batch {i}: +{len(batch)} rows -> {len(freq)} itemsets; "
              f"incremental {t_inc:.2f}s vs full re-mine {t_full:.2f}s "
              f"({t_full / max(t_inc, 1e-9):.1f}x) — exact match")
    print(f"guided-recount stats: {miner.state.stats}")


if __name__ == "__main__":
    main()
