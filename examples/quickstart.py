"""Quickstart: the paper's §4.2 worked example, end to end.

Runs the Minority-Report Algorithm (GFP-growth inside) on the 8-transaction
database of Table 1 and prints every intermediate the paper prints —
item selection, TIS-tree counts, g-counts, and the five rules — then runs the
same mine on the TPU-native dense engine and shows they agree.

  PYTHONPATH=src python examples/quickstart.py
"""
from repro.core import minority_report
from repro.mining import minority_report_dense

DB = [
    (list("facdgimp"), 0),   # TID 100
    (list("abcflmo"), 0),    # TID 200
    (list("bfhjo"), 0),      # TID 300
    (list("bcksp"), 0),      # TID 400
    (list("afcelpmn"), 0),   # TID 500
    (list("fm"), 1),         # TID 600
    (list("c"), 1),          # TID 700
    (list("b"), 1),          # TID 800
]


def main() -> None:
    tx = [t for t, _ in DB]
    y = [c for _, c in DB]

    print("=== paper-faithful engine (FP-trees + GFP-growth) ===")
    res = minority_report(tx, y, min_support=0.125, min_confidence=0.2)
    print(f"I' (items frequent in rare class): {sorted(res.items_kept)}")
    print(f"TIS-tree: {res.tis.n_targets} target itemsets")
    for key, c1 in sorted(res.tis.as_dict('count').items()):
        g = res.tis.as_dict('g_count')[key]
        print(f"  {{{','.join(map(str, key))}}}: count(C1)={c1} g-count(C0)={g}")
    print("rules:")
    for r in res.rules:
        print("  ", r)
    print(f"GFP stats: {res.stats}")

    print("\n=== TPU-native dense engine (bitmaps + Pallas kernel) ===")
    dres = minority_report_dense(tx, y, min_support=0.125, min_confidence=0.2)
    for r in dres.rules:
        print("  ", r)
    a = {r.antecedent: (r.count, r.g_count) for r in res.rules}
    b = {r.antecedent: (r.count, r.g_count) for r in dres.rules}
    assert a == b
    print(f"\nengines agree on all {len(a)} rules "
          f"({dres.kernel_launches} kernel launches)")


if __name__ == "__main__":
    main()
