"""Online minority-rule serving demo — MRA rules over the count path.

The paper's headline application (Algorithm 4.1) as an online service:
``RuleServer`` layers minority-class rules (antecedent -> class, confidence
= C1/(C1+C0)) on the resident count server.  Rule queries ride the same
micro-batched counting path; a rule cache keyed on (antecedent, version,
min_conf) answers hot keys without touching the device; appends purge stale
verdicts and PREFETCH the hottest keys at the new version; and
``top_rules`` runs the full §5.1 workload — class-guided resumable mining +
``optimal_rule_set`` filtering — against the live store.

Serving API:

    ruler = RuleServer(CountServer(tx, classes=y))
    ruler.rules_for([(2, 5), (7,)], min_conf=0.3)   # verdicts, batched+cached
    ruler.top_rules(theta, min_conf, optimal=True)  # the optimal rule set
    ruler.append(new_tx, classes=new_y)             # purge + hot-key prefetch

Every served rule is bit-exact against the host ``minority_report`` +
``optimal_rule_set`` oracle on the same history — asserted below over TWO
append rounds.

  PYTHONPATH=src python examples/rule_server.py [rows] [append_rows]
"""
import sys
import time

import numpy as np

from repro.core import minority_report, optimal_rule_set
from repro.data import bernoulli_db
from repro.serve import CountServer, RuleServer

THETA, MIN_CONF = 0.02, 0.12


def main() -> None:
    rows = int(sys.argv[1]) if len(sys.argv) > 1 else 8000
    append_rows = int(sys.argv[2]) if len(sys.argv) > 2 else 1000

    tx, y = bernoulli_db(rows, 32, p_x=0.15, p_y=0.15, seed=3)
    ruler = RuleServer(CountServer(tx, classes=list(y)), prefetch_top=8)
    st = ruler.server.store
    print(f"resident {st.resident} DB: {st.n_rows} rows, {st.vocab.size} "
          f"items, version {st.version}")

    # ---- the full minority rule set, served from the store -----------------
    hist, ys = [list(t) for t in tx], list(y)
    t0 = time.time()
    rules = ruler.top_rules(THETA, MIN_CONF)
    opt = ruler.top_rules(THETA, MIN_CONF, optimal=True)
    print(f"top_rules(theta={THETA}, min_conf={MIN_CONF}): {len(rules)} "
          f"rules, {len(opt)} optimal ({time.time() - t0:.2f}s)")
    for r in opt[:3]:
        print(f"  {r}")
    res = minority_report(hist, ys, target_class=1, min_support=THETA,
                          min_confidence=MIN_CONF)
    assert rules == res.rules and opt == optimal_rule_set(res.rules)
    print(f"  == host minority_report/optimal_rule_set oracle "
          f"({len(res.rules)} rules)")

    # ---- hot rule queries hit the (antecedent, version, min_conf) cache ----
    hot = [r.antecedent for r in rules[:8]]
    ruler.rules_for(hot, min_conf=MIN_CONF)          # warm
    t0 = time.time()
    ruler.rules_for(hot, min_conf=MIN_CONF)          # pure cache hits
    print(f"hot repeat of {len(hot)} rule queries: "
          f"{1e6 * (time.time() - t0):.0f} us "
          f"(rule-cache hit rate {ruler.cache.hit_rate:.2f})")

    # ---- growth: two appends, rules re-verified at every version -----------
    for rnd in range(2):
        batch, yb = bernoulli_db(append_rows, 32, p_x=0.18, p_y=0.15,
                                 seed=10 + rnd)
        t0 = time.time()
        v = ruler.append(batch, classes=list(yb))    # purge + prefetch
        hist += [list(t) for t in batch]
        ys += list(yb)
        served = ruler.rules_for(hot, min_conf=MIN_CONF)
        res = minority_report(hist, ys, target_class=1, min_support=THETA,
                              min_confidence=MIN_CONF)
        assert ruler.top_rules(THETA, MIN_CONF) == res.rules
        assert ruler.top_rules(THETA, MIN_CONF, optimal=True) \
            == optimal_rule_set(res.rules)
        oracle = {r.antecedent: r for r in res.rules}
        assert all(rule == oracle.get(key)
                   for key, rule in zip(hot, served))
        print(f"append -> v{v} (+{len(batch)} rows, "
              f"{time.time() - t0:.2f}s): {len(res.rules)} rules, still == "
              f"host oracle; prefetched {ruler.n_prefetched_keys} hot keys "
              f"so far")
    s = ruler.stats()
    print(f"served {s['rule_queries']} rule queries; rule cache "
          f"{s['rule_cache']['hits']} hits / {s['rule_cache']['misses']} "
          f"misses; {s['prefetches']} prefetch rounds")


if __name__ == "__main__":
    main()
